"""Compare a fresh ``kernel_bench --json`` run against the committed
baseline (``BENCH_kernels.json``) and fail on step-time regressions;
with ``--frontier`` instead guard a ``plan_frontier`` BENCH JSON.

CPU/interpret-mode wall-times are trend-only: absolute numbers vary with
the host, so every timing is normalized twice before comparison — first by
the same run's plain-matmul time (``kernel/matmul_plain_512``, cancels raw
host speed), then by the median of all normalized ratios (cancels the
class-wide drift between interpret-mode Pallas emulation and native XLA
across hosts/jax versions).  A regression is an entry that got slower
relative to its *peers* in the same run.  Counter records
(``unit=tile_qdqs`` etc.) are compared exactly: analytic quantize-work
counts must never silently grow.

Exit code 1 if any timing ratio regresses by more than ``--threshold``
(default 15%) or any counter grows.

Usage:
    python -m benchmarks.check_bench BENCH_kernels.json fresh.json
    python -m benchmarks.check_bench --frontier BENCH_plan_frontier.json
    python -m benchmarks.check_bench --step BENCH_step.json fresh_step.json
    python -m benchmarks.check_bench --decode BENCH_decode.json [fresh.json]
"""
from __future__ import annotations

import argparse
import json
import re
import sys

NORM_KEY = "kernel/matmul_plain_512"
# Entries below this absolute time (us) are too noisy for a ratio gate.
MIN_US = 200.0
# Kernel entries that must exist in BOTH files: losing one (a renamed or
# dropped bench) would silently remove its regression guard.  Covers the
# three fused matmul roles and the flash-attention forward kernel.
REQUIRED = (
    "kernel/qmm256_ffn_paper_fwd_pallas_fused",
    "kernel/qmm256_ffn_paper_dgrad_wgrad_pallas_fused",
    "kernel/qmm256_ffn_paper_fwd_pallas_stream",
    "kernel/qmm256_ffn_paper_dgrad_wgrad_pallas_stream",
    "kernel/qmm256_ffn_paper_fwd_stream_t128",
    "kernel/qmm256_ffn_paper_fwd_two_pass_t128",
    "kernel/flash_attention_fwd_256",
)


# Entries a plan_frontier BENCH JSON must contain (mirrors the kernel
# REQUIRED guard): losing one would silently drop the searcher's frontier
# from CI.  point00 is the uniform start plan; the acceptance row encodes
# the cheaper-than-fine_grained / better-than-uniform-FP4 contract.
REQUIRED_FRONTIER = ("plan_frontier/points", "plan_frontier/point00",
                     "plan_frontier/acceptance")
_POINT_RE = re.compile(r"^plan_frontier/point\d+$")

# BENCH_step.json (benchmarks.profile_report) guard: the two recipe smoke
# runs gate the fp4/bf16 step-time ratio; phase entries are required-
# presence only (jit-delta phases are too noisy for a ratio gate on CPU).
REQUIRED_STEP = ("step/train_step_fp4", "step/train_step_bf16",
                 "step/phase_fwd", "step/phase_bwd", "step/phase_optim",
                 "step/phase_quantize", "step/telemetry_overhead")
STEP_PCT_FIELDS = ("p50_us", "p95_us", "p99_us")

# BENCH_decode.json (benchmarks.decode_microbenchmark) guard: the full
# weights x KV-cache precision matrix must be present, plus the per-slot
# loop baseline, the batched/loop ratio and the measured packed sizes.
REQUIRED_DECODE = tuple(
    f"decode/{stage}_w{w}_kv{kv}"
    for w in ("bf16", "fp8", "fp4")
    for kv in ("bf16", "fp8")
    for stage in ("prefill", "insert", "generate")
) + ("decode/generate_per_slot_loop", "decode/batched_speedup",
     "decode/bytes_per_param_fp4", "decode/bytes_per_param_fp8")
DECODE_NORM = "decode/generate_wbf16_kvbf16"
# Acceptance contracts: batched generate beats the per-slot loop, and the
# packed representations actually shrink (payload + scale overhead; bf16
# would be 2.0 bytes/param).
MAX_BYTES_PER_PARAM = {"decode/bytes_per_param_fp4": 0.7,
                       "decode/bytes_per_param_fp8": 1.2}


def _load(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: r for r in payload["benchmarks"]}


def _derived_float(rec: dict, key: str) -> float:
    m = re.search(rf"{key}=([-+0-9.eE]+)", rec.get("derived", ""))
    return float(m.group(1)) if m else float("nan")


def check_frontier(path: str) -> int:
    """Required-entry + monotonicity guard for a plan_frontier JSON."""
    data = _load(path)
    failures = [f"required entry missing: {n}" for n in REQUIRED_FRONTIER
                if n not in data]
    # numeric point order (lexicographic would shuffle point100 before
    # point99 on long frontiers)
    names = sorted((n for n in data if _POINT_RE.match(n)),
                   key=lambda n: int(n.rsplit("point", 1)[1]))
    pts = [data[n] for n in names]
    costs = [_derived_float(r, "cost") for r in pts]
    errs = [_derived_float(r, "error") for r in pts]
    for i in range(1, len(pts)):
        if not (costs[i] > costs[i - 1] and errs[i] < errs[i - 1]):
            failures.append(
                f"frontier not monotone at point{i:02d}: "
                f"cost {costs[i - 1]:.6f} -> {costs[i]:.6f}, "
                f"error {errs[i - 1]:.6f} -> {errs[i]:.6f}")
    if "plan_frontier/acceptance" in data and \
            data["plan_frontier/acceptance"]["us_per_call"] < 1.0:
        failures.append("acceptance contract not met: "
                        + data["plan_frontier/acceptance"]["derived"])
    print(f"[check_bench] frontier: {len(pts)} points in {path}")
    if failures:
        print("[check_bench] FAILURES:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print("[check_bench] frontier guard passed")
    return 0


def check_step(baseline: str, current: str, threshold: float) -> int:
    """BENCH_step.json guard: required entries + percentile fields in
    both files, then the fp4/bf16 median-step-time ratio compared across
    runs.  Normalizing fp4 by the same run's bf16 step cancels raw host
    speed (the same trick as the kernel gate's NORM_KEY), so the gate
    trips only when FP4 training got slower *relative to the bf16
    baseline measured on the same machine*."""
    base, cur = _load(baseline), _load(current)
    failures = [f"required entry missing from {tag}: {name}"
                for name in REQUIRED_STEP
                for tag, d in (("baseline", base), ("current", cur))
                if name not in d]
    for tag, d in (("baseline", base), ("current", cur)):
        for name in ("step/train_step_fp4", "step/train_step_bf16"):
            rec = d.get(name)
            if rec is None:
                continue
            for field in STEP_PCT_FIELDS:
                if field not in rec:
                    failures.append(f"{tag} {name}: missing percentile "
                                    f"field {field}")
        # A negative phase share is impossible by construction — it means
        # the report emitted a raw noisy delta instead of clamping it
        # (profile_report marks clamped rows with noise=true instead).
        for name, rec in d.items():
            if not name.startswith("step/phase_"):
                continue
            share = _derived_float(rec, "share")
            if share == share and share < 0:  # NaN-safe
                failures.append(f"{tag} {name}: negative share "
                                f"{share:.3f} (impossible; expected "
                                f"clamped-to-zero + noise=true)")
    if failures:
        print("[check_bench] FAILURES:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1

    def rel(d):
        return (d["step/train_step_fp4"]["p50_us"]
                / d["step/train_step_bf16"]["p50_us"])

    ratio = rel(cur) / rel(base)
    print(f"[check_bench] step: fp4/bf16 p50 ratio baseline "
          f"{rel(base):.3f}, current {rel(cur):.3f} "
          f"({ratio:.3f}x baseline)")
    if ratio > 1.0 + threshold:
        print(f"[check_bench] FAILURES:", file=sys.stderr)
        print(f"  step/train_step_fp4: fp4/bf16 step-time ratio regressed "
              f"{ratio:.3f}x (> {1 + threshold:.2f}x)", file=sys.stderr)
        return 1
    print("[check_bench] step guard passed")
    return 0


def _check_decode_one(tag: str, data: dict) -> list:
    """Required entries + acceptance contracts for one BENCH_decode file."""
    failures = [f"required entry missing from {tag}: {n}"
                for n in REQUIRED_DECODE if n not in data]
    sp = data.get("decode/batched_speedup")
    if sp is not None:
        ratio = _derived_float(sp, "ratio")
        if ratio != ratio:  # NaN-safe fallback to the (rounded) value
            ratio = sp["us_per_call"]
        if not ratio < 1.0:
            failures.append(f"{tag}: batched generate does not beat the "
                            f"per-slot loop (ratio {ratio:.3f} >= 1.0)")
    for name, limit in MAX_BYTES_PER_PARAM.items():
        rec = data.get(name)
        if rec is not None and rec["us_per_call"] > limit:
            failures.append(f"{tag} {name}: {rec['us_per_call']:.3f} "
                            f"bytes/param > {limit} (packing regressed)")
    for name, rec in data.items():
        if name.startswith("decode/generate") and name != \
                "decode/batched_speedup":
            for field in STEP_PCT_FIELDS:
                if field not in rec:
                    failures.append(f"{tag} {name}: missing percentile "
                                    f"field {field}")
    return failures


def check_decode(baseline: str, current, threshold: float) -> int:
    """BENCH_decode.json guard.

    One file: required-entry + acceptance check (batched beats the loop,
    packed bytes/param within bounds, percentile fields present).  With a
    second (fresh) file, additionally gate generate-stage regressions:
    each generate entry is normalized by the same run's bf16/bf16 generate
    (cancels raw host speed) and compared across runs.
    """
    base = _load(baseline)
    failures = _check_decode_one("baseline", base)
    if current:
        cur = _load(current)
        failures += _check_decode_one("current", cur)
        if DECODE_NORM in base and DECODE_NORM in cur:
            bn = base[DECODE_NORM]["us_per_call"]
            cn = cur[DECODE_NORM]["us_per_call"]
            for name in sorted(base):
                if not name.startswith("decode/generate_w") or \
                        name == DECODE_NORM or name not in cur:
                    continue
                ratio = (cur[name]["us_per_call"] / cn) \
                    / (base[name]["us_per_call"] / bn)
                status = "ok"
                if ratio > 1.0 + threshold:
                    status = "REGRESSED"
                    failures.append(
                        f"{name}: {ratio:.3f}x the normalized baseline "
                        f"(> {1 + threshold:.2f}x)")
                print(f"[check_bench] {name}: {ratio:.3f}x normalized "
                      f"baseline ({status})")
    if failures:
        print("[check_bench] FAILURES:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print("[check_bench] decode guard passed")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", nargs="?")
    ap.add_argument("current", nargs="?")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="allowed relative regression of normalized time")
    ap.add_argument("--frontier", default=None, metavar="JSON",
                    help="guard a plan_frontier BENCH JSON (required "
                    "entries + frontier monotonicity) and exit")
    ap.add_argument("--step", action="store_true",
                    help="treat baseline/current as BENCH_step.json "
                    "(profile_report) files: required entries + "
                    "percentile fields + fp4/bf16 step-time ratio gate")
    ap.add_argument("--decode", action="store_true",
                    help="treat baseline (and optionally current) as "
                    "BENCH_decode.json (decode_microbenchmark) files: "
                    "required entries + acceptance (batched beats the "
                    "per-slot loop, packed bytes/param bounds) + "
                    "generate-stage regression gate when two files given")
    args = ap.parse_args(argv)

    if args.frontier:
        return check_frontier(args.frontier)
    if args.decode:
        if not args.baseline:
            ap.error("--decode requires at least a baseline file")
        return check_decode(args.baseline, args.current, args.threshold)
    if not args.baseline or not args.current:
        ap.error("baseline and current are required unless --frontier")
    if args.step:
        return check_step(args.baseline, args.current, args.threshold)

    base, cur = _load(args.baseline), _load(args.current)
    if NORM_KEY not in base or NORM_KEY not in cur:
        print(f"[check_bench] missing normalizer {NORM_KEY}", file=sys.stderr)
        return 1
    missing = [(tag, name) for name in REQUIRED
               for tag, d in (("baseline", base), ("current", cur))
               if name not in d]
    if missing:
        for tag, name in missing:
            print(f"[check_bench] required entry missing from {tag}: "
                  f"{name}", file=sys.stderr)
        return 1
    bn, cn = base[NORM_KEY]["us_per_call"], cur[NORM_KEY]["us_per_call"]

    failures, compared, timing = [], 0, []
    for name, brec in sorted(base.items()):
        if name == NORM_KEY or name not in cur:
            continue
        crec = cur[name]
        is_counter = (brec.get("unit", "us") != "us"
                      or "unit=" in brec.get("derived", ""))
        if is_counter:  # analytic counter, compared exactly
            compared += 1
            if crec["us_per_call"] > brec["us_per_call"]:
                failures.append(
                    f"{name}: counter grew {brec['us_per_call']} -> "
                    f"{crec['us_per_call']}")
            continue
        if brec["us_per_call"] < MIN_US:
            continue
        compared += 1
        ratio = (crec["us_per_call"] / cn) / (brec["us_per_call"] / bn)
        timing.append((name, ratio))

    # Interpret-mode Pallas (Python emulation) and the native-XLA normalizer
    # scale differently across hosts, so the whole entry class can drift
    # together on a different machine.  Dividing by the median ratio cancels
    # that class-wide drift; only entries that regress RELATIVE to their
    # peers trip the gate.
    med = sorted(r for _, r in timing)[len(timing) // 2] if timing else 1.0
    for name, ratio in timing:
        rel = ratio / med
        status = "ok"
        if rel > 1.0 + args.threshold:
            status = "REGRESSED"
            failures.append(f"{name}: {rel:.3f}x the run's median-adjusted "
                            f"baseline (> {1 + args.threshold:.2f}x)")
        print(f"[check_bench] {name}: {ratio:.3f}x baseline, "
              f"{rel:.3f}x median-adjusted ({status})")

    print(f"[check_bench] compared {compared} entries "
          f"(norm: baseline {bn:.0f}us, current {cn:.0f}us)")
    if failures:
        print("[check_bench] FAILURES:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print("[check_bench] no regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
