"""Table 2: module-wise precision ablation (LLaMA-shaped bench model).

Paper rows (LLaMA2-125M, 5B tokens):
  FP4 attn | FP4 ffn | FP4 bwd  -> worst   (57.1% cost)
  FP8 attn | FP4 ffn | FP4 bwd  -> better  (60.7%)
  FP8 attn | FP4 ffn | FP8 bwd  -> better  (66.1%)
  FP4 attn | FP8 ffn | FP8 bwd  -> better  (69.6%)
  FP16 everywhere               -> best    (100%)

We reproduce the loss ORDERING and report both our analytic and the
paper-calibrated theoretical cost per row.
"""
from __future__ import annotations

from benchmarks.common import BENCH_LLAMA, emit, train_once
from repro.core.cost_model import (BlockDims, paper_calibrated_cost,
                                   theoretical_cost)
from repro.core.recipe import RECIPES

ROWS = ["all_fp4", "t2_fp8_fp4_fp4", "t2_fp8_fp4_fp8", "t2_fp4_fp8_fp8",
        "bf16"]

_DIMS = BlockDims(d_model=768, d_ff=3072, n_heads=12, n_kv_heads=12,
                  head_dim=64, seq_len=2048, n_ff_matmuls=3)


def run(steps: int = 300) -> dict:
    out = {}
    for name in ROWS:
        r = train_once(BENCH_LLAMA, name, steps=steps)
        cal = paper_calibrated_cost(RECIPES[name])
        ana = theoretical_cost(RECIPES[name], _DIMS)
        out[name] = dict(r, cost_cal=cal, cost_analytic=ana)
        emit(f"table2/{name}", r["us_per_step"],
             f"train_loss={r['train_loss']:.4f};val_loss={r['val_loss']:.4f};"
             f"val_ppl={r['val_ppl']:.3f};cost_paper={cal:.3f};"
             f"cost_analytic={ana:.3f}")
    ordered = sorted(ROWS, key=lambda n: out[n]["val_loss"])
    emit("table2/val_loss_ranking", 0.0, ">".join(reversed(ordered)))
    return out


if __name__ == "__main__":
    run()
