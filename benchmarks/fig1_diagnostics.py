"""Fig. 1 diagnostics.

(a) compute-share of a transformer block (LLaMA-7B @ 4k: FFN ~57%);
(b) FP4-vs-FP8 underflow rates measured on REAL gradients/activations from
    a short training run (paper: grads ~8.6%, activations ~18%);
(c) attention-score distortion: entropy of attention probabilities under
    all-FP4 vs attention-protected training (paper: all-FP4 flattens the
    attention map towards uniform).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_GPT, BENCH_LLAMA, emit, train_once
from repro.core.cost_model import BlockDims, compute_share
from repro.core.quantize import QuantSpec, underflow_rate
from repro.core.recipe import RECIPES


def fig1a() -> None:
    d = BlockDims(d_model=4096, d_ff=11008, n_heads=32, n_kv_heads=32,
                  head_dim=128, seq_len=4096, n_ff_matmuls=3)
    share = compute_share(d)
    emit("fig1a/compute_share", 0.0,
         ";".join(f"{k}={v:.3f}" for k, v in share.items()))


def fig1b(steps: int = 150) -> None:
    """Collect grads + activations mid-training, measure underflow."""
    r = train_once(BENCH_LLAMA, "bf16", steps=steps)
    st, tr = r["state"], r["trainer"]
    model, tcfg = tr.model, tr.tcfg
    batch = {k: jnp.asarray(v) for k, v in tr.pipeline.batch(999).items()}

    def loss_fn(p):
        return model.loss(p, batch, RECIPES["bf16"])[0]

    grads = jax.grad(loss_fn)(st.params)
    flat_g = jnp.concatenate([g.astype(jnp.float32).ravel()
                              for g in jax.tree.leaves(grads)
                              if g.ndim >= 2])
    # activations: hidden states before the head
    h, _ = model.hidden(st.params, batch, RECIPES["bf16"])
    flat_a = h.astype(jnp.float32).reshape(-1, h.shape[-1])

    for tag, arr, axis in (("grad", flat_g.reshape(1, -1), 1),
                           ("act", flat_a, 1)):
        u4 = float(underflow_rate(arr, QuantSpec("fp4_e2m1", "tensor"), axis))
        u8 = float(underflow_rate(arr, QuantSpec("fp8_e4m3", "tensor"), axis))
        u4b = float(underflow_rate(arr, QuantSpec("fp4_e2m1", "block", 128),
                                   axis))
        emit(f"fig1b/underflow_{tag}", 0.0,
             f"fp4_tensor={u4:.4f};fp8_tensor={u8:.4f};fp4_block128={u4b:.4f}")
    emit("fig1b/grad_abs_mean", 0.0,
         f"mean={float(jnp.abs(flat_g).mean()):.5f}")


def fig1c_direct() -> None:
    """Direct Fig 1(c) mechanism: with FIXED (bf16-trained) weights, compute
    attention probabilities from QKV projections quantized at each precision.
    Quantization noise in Q/K decorrelates scores -> higher (more uniform)
    entropy — no training confound."""
    from repro.core.qlinear import qlinear
    from repro.core.recipe import MM_BF16, MM_FP4_ALL, MM_FP8
    r = train_once(BENCH_GPT, "bf16", steps=250)
    st, tr = r["state"], r["trainer"]
    model = tr.model
    cfg = model.cfg
    batch = {k: jnp.asarray(v) for k, v in tr.pipeline.batch(7).items()}
    params = model.cast_params(st.params)
    x = model._embed(params, batch["tokens"])
    lp = jax.tree.map(lambda p: p[0], params["stack"]["groups"])["l00"]
    from repro.nn.layers import apply_norm
    h = apply_norm(lp["mixer_norm"], x, cfg.norm)
    b, s, _ = h.shape
    hd = cfg.resolved_head_dim
    ents = {}
    for name, rec in (("bf16", MM_BF16), ("fp8", MM_FP8),
                      ("fp4", MM_FP4_ALL)):
        q = qlinear(h, lp["mixer"]["wq"], rec).reshape(b, s, cfg.n_heads, hd)
        k = qlinear(h, lp["mixer"]["wk"], rec).reshape(b, s, cfg.n_kv_heads,
                                                       hd)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                            k.astype(jnp.float32)) / np.sqrt(hd)
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        ent = -jnp.sum(p * jnp.log(jnp.maximum(p, 1e-20)), axis=-1)
        norm = jnp.log(jnp.arange(1, s + 1, dtype=jnp.float32))
        ents[name] = float((ent[..., 1:] / norm[1:]).mean())
        emit(f"fig1c/direct_entropy_{name}", 0.0,
             f"normalized_entropy={ents[name]:.4f}")
    emit("fig1c/direct_flattening", 0.0,
         f"fp4_minus_bf16={ents['fp4'] - ents['bf16']:.4f};"
         f"fp8_minus_bf16={ents['fp8'] - ents['bf16']:.4f}")


def fig1c(steps: int = 250) -> None:
    """Attention-probability entropy after training under each recipe."""
    from repro.models.attention import chunked_attention
    ents = {}
    for recipe in ("paper_fp4", "all_fp4"):
        r = train_once(BENCH_GPT, recipe, steps=steps)
        st, tr = r["state"], r["trainer"]
        model = tr.model
        batch = {k: jnp.asarray(v) for k, v in tr.pipeline.batch(7).items()}
        # probe: logits sensitivity as attention-sharpness proxy — compute
        # per-layer attention entropy by rerunning layer 0's attention.
        params = model.cast_params(st.params)
        cfg = model.cfg
        x = model._embed(params, batch["tokens"])
        lp = jax.tree.map(lambda p: p[0], params["stack"]["groups"])["l00"]
        from repro.nn.layers import apply_norm
        h = apply_norm(lp["mixer_norm"], x, cfg.norm)
        from repro.core.qlinear import qlinear
        rec = RECIPES[recipe].attn_linear
        b, s, _ = h.shape
        hd = cfg.resolved_head_dim
        q = qlinear(h, lp["mixer"]["wq"], rec).reshape(b, s, cfg.n_heads, hd)
        k = qlinear(h, lp["mixer"]["wk"], rec).reshape(b, s, cfg.n_kv_heads,
                                                       hd)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                            k.astype(jnp.float32)) / np.sqrt(hd)
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        ent = -jnp.sum(p * jnp.log(jnp.maximum(p, 1e-20)), axis=-1)
        # normalized by log(row length) -> 1.0 == uniform
        norm = jnp.log(jnp.arange(1, s + 1, dtype=jnp.float32))
        ent_n = float((ent[..., 1:] / norm[1:]).mean())
        ents[recipe] = ent_n
        emit(f"fig1c/attn_entropy_{recipe}", r["us_per_step"],
             f"normalized_entropy={ent_n:.4f};val_loss={r['val_loss']:.4f}")
    emit("fig1c/entropy_gap", 0.0,
         f"all_fp4_minus_protected={ents['all_fp4'] - ents['paper_fp4']:.4f}")


def run() -> None:
    fig1a()
    fig1b()
    fig1c_direct()
    fig1c()


if __name__ == "__main__":
    run()
