"""Table 1: FP4-recipe pretraining ~ BF16 pretraining (val loss / PPL).

The paper trains GPT-2 {125M, 335M, 774M} on 10-25B tokens; this CPU-scale
reproduction trains the GPT-shaped bench config on ~0.3M tokens and checks
the CONTRACT: paper-recipe FP4 val loss lands within a small gap of BF16
(paper: 1.706 vs 1.705 etc.), while all-FP4 (Table 2 row 1) is clearly
worse.
"""
from __future__ import annotations

from benchmarks.common import BENCH_GPT, emit, train_once


def run(steps: int = 300) -> dict:
    out = {}
    for recipe in ("bf16", "paper_fp4"):
        r = train_once(BENCH_GPT, recipe, steps=steps)
        out[recipe] = r
        emit(f"table1/gpt_{recipe}", r["us_per_step"],
             f"val_loss={r['val_loss']:.4f};val_ppl={r['val_ppl']:.2f};"
             f"train_loss={r['train_loss']:.4f}",
             extra={k: r[k] for k in ("p50_us", "p95_us", "p99_us")
                    if k in r})
    gap = out["paper_fp4"]["val_loss"] - out["bf16"]["val_loss"]
    emit("table1/fp4_minus_bf16_val_loss", 0.0, f"gap={gap:.4f}")
    return out


if __name__ == "__main__":
    run()
