"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from
artifacts/dryrun JSONs.  Run after the sweep:

    PYTHONPATH=src python -m benchmarks.gen_experiments > /tmp/sections.md
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

ART = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")
ARCH_ORDER = [
    "nemotron-4-15b", "llama3.2-3b", "h2o-danube-3-4b", "granite-34b",
    "mixtral-8x22b", "olmoe-1b-7b", "llama-3.2-vision-90b", "whisper-base",
    "mamba2-780m", "jamba-1.5-large-398b",
]
CELL_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh):
    out = {}
    for path in glob.glob(os.path.join(ART, f"*__{mesh}__*.json")):
        with open(path) as f:
            d = json.load(f)
        out[(d["arch"], d["cell"])] = d
    return out


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_section(single, multi):
    print("## Dry-run (16x16 single-pod and 2x16x16 multi-pod)\n")
    print("Every (arch x shape) cell lowered + compiled with"
          " `.lower().compile()` on both production meshes"
          " (`repro.launch.dryrun`).  `mem/chip` = argument+temp+output"
          " bytes per device from `memory_analysis()` (XLA:CPU's bf16->f32"
          " legalization inflates temp ~2-3x vs a TPU build; see DESIGN.md"
          " §8).  Skips are per-assignment (sub-quadratic-only cells).\n")
    print("| arch | cell | multi-pod compile | multi mem/chip | single-pod"
          " compile | status |")
    print("|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for c in CELL_ORDER:
            m = multi.get((a, c))
            s = single.get((a, c))
            if m is None and s is None:
                continue
            if (m or s)["status"] == "skipped":
                print(f"| {a} | {c} | — | — | — | skipped:"
                      f" {(m or s)['reason'][:58]} |")
                continue
            mm = (f"{m['compile_s']:.1f}s" if m and m["status"] == "ok"
                  else (m or {}).get("status", "—"))
            mg = (f"{m['memory']['peak_estimate_gb']:.1f} GB"
                  if m and m["status"] == "ok" else "—")
            ss = (f"{s['compile_s']:.1f}s" if s and s["status"] == "ok"
                  else (s or {}).get("status", "—"))
            ok = "ok" if (not m or m["status"] == "ok") and \
                (not s or s["status"] == "ok") else "PARTIAL"
            print(f"| {a} | {c} | {mm} | {mg} | {ss} | {ok} |")
    print()


def roofline_section(single):
    print("## Roofline (single-pod 16x16 = 256 chips, TPU v5e terms)\n")
    print("Terms in per-chip seconds: compute = FLOPs/197e12, memory ="
          " bytes/819e9, collective = ring-effective bytes/50e9."
          "  FLOPs from unrolled-probe differencing (exact for the layer"
          " stack) + analytic corrections for interior scans;"
          " `useful` = MODEL_FLOPS/(HLO_FLOPs*chips); `MFU@bound` ="
          " MODEL_FLOPS/(chips*peak*bound).\n")
    print("| arch | cell | compute | memory | collective | bottleneck |"
          " useful | MFU@bound |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for c in CELL_ORDER:
            d = single.get((a, c))
            if d is None or d["status"] == "skipped":
                continue
            if d["status"] != "ok":
                print(f"| {a} | {c} | — | — | — | {d['status']} | — | — |")
                continue
            t = d["roofline"]
            print(f"| {a} | {c} | {fmt_s(t['compute_s'])} |"
                  f" {fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} |"
                  f" {t['bottleneck']} |"
                  f" {t.get('useful_flops_ratio', 0):.3f} |"
                  f" {t.get('mfu_at_bound', 0):.3f} |")
    print()
    # bottleneck histogram + worst cells (hillclimb candidates)
    by = defaultdict(list)
    for (a, c), d in single.items():
        if d["status"] == "ok":
            by[d["roofline"]["bottleneck"]].append(
                (d["roofline"].get("mfu_at_bound", 0), a, c))
    print("### Bottleneck summary\n")
    for k, v in sorted(by.items()):
        worst = sorted(v)[:3]
        print(f"- **{k}**: {len(v)} cells; worst MFU@bound: "
              + ", ".join(f"{a}/{c} ({m:.3f})" for m, a, c in worst))
    print()


def main():
    single, multi = load("single"), load("multi")
    dryrun_section(single, multi)
    roofline_section(single)


if __name__ == "__main__":
    main()
