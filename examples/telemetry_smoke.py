"""Telemetry + adaptive-controller smoke run (CI; CPU; ~10 steps).

Trains the tiny config with in-graph telemetry, the JSONL writer, and the
PrecisionController enabled, then renders the markdown report.  Exits
nonzero if telemetry metrics are missing from the history or the JSONL log.

    python examples/telemetry_smoke.py [--steps 10] [--out artifacts/telemetry]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.configs.base import ControllerSettings, TrainConfig, get_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train.trainer import Trainer


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--out", default="artifacts/telemetry")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    jsonl = os.path.join(args.out, "telemetry.jsonl")

    cfg = get_config("tiny")
    model = build_model(cfg)
    pipe = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    tcfg = TrainConfig(
        recipe="paper_fp4", total_steps=args.steps, global_batch=8,
        seq_len=64, learning_rate=3e-3, log_every=1,
        telemetry=True, telemetry_jsonl=jsonl,
        checkpoint_every=max(args.steps // 2, 1),
        checkpoint_dir=os.path.join(args.out, "ckpt"),
        controller=ControllerSettings(switch_error_threshold=10.0,
                                      demote_overflow_threshold=0.5,
                                      spike_factor=3.0))
    tr = Trainer(model, tcfg, pipe)
    tr.train(log=print)

    row = tr.history[-1]
    tel_keys = [k for k in row if k.startswith("tel/")]
    print(f"[smoke] {len(tel_keys)} telemetry metrics in history")
    if not tel_keys:
        print("[smoke] FAIL: no telemetry metrics collected")
        return 1
    if not os.path.exists(jsonl):
        print("[smoke] FAIL: JSONL log missing")
        return 1

    # measured step-time profile (StepTimer percentiles + MFU) + async
    # writer health -> the CI-uploaded profiler artifact
    import json
    summ = tr.step_time_summary()
    summ["writer_dropped"] = tr.writer.dropped
    summary_path = os.path.join(args.out, "profiler_summary.json")
    with open(summary_path, "w") as f:
        json.dump(summ, f, indent=2)
    print(f"[smoke] profiler summary -> {summary_path}")
    if not summ.get("steps"):
        print("[smoke] FAIL: no post-warmup step timings recorded")
        return 1
    if summ["writer_dropped"]:
        print(f"[smoke] FAIL: async writer dropped "
              f"{summ['writer_dropped']} rows")
        return 1

    from benchmarks.telemetry_report import build_report
    from repro.telemetry.writer import read_jsonl
    report = build_report(read_jsonl(jsonl))
    report_path = os.path.join(args.out, "report.md")
    with open(report_path, "w") as f:
        f.write(report + "\n")
    print(f"[smoke] report -> {report_path}")
    print(report)
    return 0


if __name__ == "__main__":
    sys.exit(main())
