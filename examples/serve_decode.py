"""Batched low-precision serving example.

Trains a tiny model briefly so generation shows the learned periodic
structure, then serves mixed-length prompts through the batched
``DecodeEngine``: packed FP4 weight panels (quantized once at load), an
FP8 KV cache, bucket-padded prefill, and a single jitted generate step
that advances every live slot at once.  A ``ContinuousBatcher`` run on
the same prompts shows the queue-driven wrapper.

    PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np

from repro.configs.base import TrainConfig, get_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train.serving_runtime import (ContinuousBatcher, DecodeEngine,
                                         quantize_weights_for_serving,
                                         serving_memory_report)
from repro.train.trainer import Trainer

SEQ = 64
N_NEW = 16


def main() -> None:
    cfg = get_config("tiny")
    model = build_model(cfg)
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=500, global_batch=8,
                       seq_len=SEQ, learning_rate=3e-3, log_every=50)
    pipe = SyntheticLM(cfg.vocab_size, SEQ, 8, noise=0.0)
    trainer = Trainer(model, tcfg, pipe)
    state = trainer.train(log=print)

    # quantize once at load: linear panels become packed uint8 + scales
    params = quantize_weights_for_serving(model, state.params, "fp4_e2m1")
    rep = serving_memory_report(params)
    print(f"\npacked fp4 weights: {rep['bytes_per_packed_param']:.3f} "
          f"bytes/param over {rep['packed_params']:,} params "
          f"({rep['vs_bf16']:.2f}x bf16 size)")

    # mixed-length prompts from the training distribution; the engine
    # bucket-pads prefill so each length reuses a compiled shape
    batch = pipe.batch(12345)
    lens = (12, 16, 10, 14)
    prompts = [np.asarray(batch["tokens"][i, :n], np.int32)
               for i, n in enumerate(lens)]
    truth = [np.asarray(batch["tokens"][i, n:n + N_NEW])
             for i, n in enumerate(lens)]

    # --- explicit engine loop: prefill -> insert -> batched generate ----
    engine = DecodeEngine(model, params, n_slots=len(prompts), max_len=SEQ,
                          kv_format="fp8_e4m3")
    for slot, p in enumerate(prompts):
        tok, c1 = engine.prefill(p)          # b=1, bucket-padded
        engine.insert(c1, tok, slot)         # splice into the slot cache
    gen = [[int(engine.last_tok[s])] for s in range(len(prompts))]
    for _ in range(N_NEW - 1):
        nxt = engine.generate_step()         # ONE jitted step, all slots
        for s in range(len(prompts)):
            gen[s].append(int(nxt[s]))

    hits = total = 0
    for s, n in enumerate(lens):
        hits += int((np.asarray(gen[s]) == truth[s]).sum())
        total += N_NEW
        print(f"slot {s} (len {n:2d}): gen {gen[s][:8]} | "
              f"truth {truth[s][:8].tolist()}")
    print(f"continuation accuracy (fp4 weights, fp8 KV): {hits/total:.2%}")

    # --- same thing via the queue-driven batcher -----------------------
    bat = ContinuousBatcher(model, params, n_slots=2, max_len=SEQ,
                            kv_format="fp8_e4m3")
    rids = [bat.submit(p, N_NEW) for p in prompts]
    out = bat.run()
    match = all(out[r] == g for r, g in zip(rids, gen))
    print(f"ContinuousBatcher (2 slots, 4 requests) matches engine: {match}")


if __name__ == "__main__":
    main()
