"""Batched serving example: prefill + greedy decode with a KV cache.

Trains a tiny model briefly so generation shows the learned periodic
structure, then serves a batch of prompts.

    PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig, get_config
from repro.core.recipe import RECIPES
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train.serve import generate
from repro.train.trainer import Trainer


def main() -> None:
    cfg = get_config("tiny")
    model = build_model(cfg)
    tcfg = TrainConfig(recipe="paper_fp4", total_steps=500, global_batch=8,
                       seq_len=64, learning_rate=3e-3, log_every=50)
    pipe = SyntheticLM(cfg.vocab_size, 64, 8, noise=0.0)
    trainer = Trainer(model, tcfg, pipe)
    state = trainer.train(log=print)

    # serve: prompts from the same distribution; model should continue the
    # periodic pattern
    batch = pipe.batch(12345)
    prompts = jnp.asarray(batch["tokens"][:4, :16])
    truth = np.asarray(batch["tokens"][:4, 16:32])
    out = generate(model, state.params, prompts, max_new_tokens=16,
                   recipe=RECIPES["bf16"])
    gen = np.asarray(out[:, 16:])
    acc = float((gen == truth).mean())
    for i in range(4):
        print(f"prompt {np.asarray(prompts)[i, -8:].tolist()} -> "
              f"gen {gen[i, :8].tolist()} | truth {truth[i, :8].tolist()}")
    print(f"continuation accuracy: {acc:.2%}")


if __name__ == "__main__":
    main()
