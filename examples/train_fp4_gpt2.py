"""End-to-end training driver: the paper's GPT-2 pretraining, scaled by CLI.

Default runs a reduced GPT-2 for a few hundred steps on CPU with
checkpointing + resume; ``--full`` selects the real GPT-2-125M config
(paper Table 4: 12L/768d, seq 1024, batch 480, lr 6e-4 — for real hardware).

    PYTHONPATH=src python examples/train_fp4_gpt2.py --steps 300
    PYTHONPATH=src python examples/train_fp4_gpt2.py --resume   # continues
"""
import argparse

from repro.configs.base import TrainConfig, get_config
from repro.data import ByteCorpus, SyntheticLM
from repro.models import build_model
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale GPT-2 125M (needs accelerators)")
    ap.add_argument("--recipe", default="paper_fp4")
    ap.add_argument("--data", default="synthetic",
                    choices=["synthetic", "bytes"])
    ap.add_argument("--ckpt", default="/tmp/repro_gpt2_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.full:
        cfg = get_config("gpt2-125m")
        tcfg = TrainConfig(recipe=args.recipe, total_steps=args.steps,
                           global_batch=480, seq_len=1024,
                           learning_rate=6e-4, weight_decay=0.1,
                           checkpoint_every=100, checkpoint_dir=args.ckpt,
                           keep_checkpoints=3, async_checkpoint=True)
    else:
        import importlib
        cfg = importlib.import_module("repro.configs.gpt2_125m").REDUCED
        cfg = cfg.replace(n_layers=4, d_model=128, d_ff=512)
        tcfg = TrainConfig(recipe=args.recipe, total_steps=args.steps,
                           global_batch=16, seq_len=128, learning_rate=2e-3,
                           checkpoint_every=100, checkpoint_dir=args.ckpt,
                           log_every=25)

    model = build_model(cfg)
    if args.data == "bytes":
        pipe = ByteCorpus(tcfg.seq_len, tcfg.global_batch)
        cfg = cfg.replace(vocab_size=256)
        model = build_model(cfg)
    else:
        pipe = SyntheticLM(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch)

    trainer = Trainer(model, tcfg, pipe)
    state = trainer.resume() if args.resume else None
    if state is not None:
        print(f"resumed from step {state.step}")
    state = trainer.train(state, log=print)
    print("final eval:", trainer.evaluate(state))


if __name__ == "__main__":
    main()
