"""Paper §4.2 in miniature: train the same model under each precision
recipe and print the Table-2-style comparison.

    PYTHONPATH=src python examples/precision_ablation.py --steps 200
"""
import argparse

from benchmarks.common import BENCH_LLAMA, train_once
from repro.core.cost_model import paper_calibrated_cost
from repro.core.recipe import RECIPES


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    rows = ["all_fp4", "t2_fp8_fp4_fp8", "paper_fp4", "fp8", "bf16"]
    print(f"{'recipe':18s} {'train':>8s} {'val':>8s} {'ppl':>8s} {'cost':>6s}")
    for name in rows:
        r = train_once(BENCH_LLAMA, name, steps=args.steps)
        cost = paper_calibrated_cost(RECIPES[name])
        print(f"{name:18s} {r['train_loss']:8.4f} {r['val_loss']:8.4f} "
              f"{r['val_ppl']:8.3f} {cost:6.3f}")


if __name__ == "__main__":
    main()
