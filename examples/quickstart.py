"""Quickstart: train a tiny LM with the paper's FP4 recipe in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs.base import TrainConfig, get_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train.trainer import Trainer


def main() -> None:
    cfg = get_config("tiny")
    model = build_model(cfg)
    tcfg = TrainConfig(
        recipe="paper_fp4",        # §3: FP8 attention, FP4 FFN, FP8 wgrad
        total_steps=120,           # last 7.5% run at target precision (§3.3)
        global_batch=8, seq_len=64, learning_rate=3e-3, log_every=20)
    pipe = SyntheticLM(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch)
    trainer = Trainer(model, tcfg, pipe)
    state = trainer.train(log=print)
    print("eval:", trainer.evaluate(state))
    print(f"params: {model.param_count():,}  "
          f"recipe: {trainer.recipe.name}  "
          f"switch step: {trainer.schedule.switch_step}")


if __name__ == "__main__":
    main()
